"""Planner subsystem tests (core/stats.py + core/planner.py).

Four invariants anchor the subsystem:

* **Order invariance** — both search engines enumerate the *identical*
  embedding set under any valid matching order (this is what makes plan
  staleness a latency concern, never a correctness one).
* **Greedy bit-identity** — the deduplicated ``greedy_matching_order``
  helper and a stats-less planner reproduce the exact orders the engines'
  old inline rule produced, so planner-off and stats-absent paths are
  regressions-proof.
* **Stats parity** — incrementally-maintained ``GraphStats`` (flat and
  sharded index) equal a from-scratch rebuild after arbitrary mutation
  sequences, with epoch versioning.
* **Cache semantics** — repeat queries hit, bucket moves invalidate,
  cached canonical plans map back to valid orders.
"""

import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    BatchQueryEngine,
    GraphStats,
    IncrementalIndex,
    PlanCache,
    QueryPlanner,
    ShardedIncrementalIndex,
    SubgraphQueryEngine,
    bfs_join_search,
    greedy_matching_order,
    host_dfs_search,
)
from repro.core.ilgf import ilgf
from repro.core.planner import canonical_form, query_fingerprint
from repro.core.search import _host_adjacency
from repro.graphs import (
    GraphStore,
    ShardedGraphStore,
    random_labeled_graph,
    random_update_batches,
    random_walk_query,
)
from repro.graphs.csr import build_graph
from repro.serve import GraphQueryService, GraphServiceConfig
from strategies import (
    emb_set as _emb_set,
    label_candidates as _label_candidates,
    random_connected_order as _random_connected_order,
)


def _legacy_greedy(sizes, q_adj):
    """The pre-planner inline rule, verbatim (regression reference)."""
    n_q = len(sizes)
    order = [int(np.argmin(sizes))]
    remaining = set(range(n_q)) - set(order)
    while remaining:
        connected = [u for u in remaining
                     if any(w in q_adj.get(u, {}) for w in order)]
        pool = connected if connected else list(remaining)
        nxt = min(pool, key=lambda u: sizes[u])
        order.append(nxt)
        remaining.remove(nxt)
    return order


def _skewed_graph_and_query(n_a=6, n_b=60, n_c=7, seed=0):
    """Label-skewed workload where greedy picks a bad starting side.

    Label 0 (A, rare) connects to *every* label-1 vertex (B, huge, zero
    selectivity); each B has exactly one label-2 neighbor (C, rare, high
    selectivity).  Greedy starts at A (smallest |C(u)|) and immediately
    materializes the A×B cross product; starting from C keeps intermediate
    tables near |B|.
    """
    rng = np.random.default_rng(seed)
    vlabels = np.array([0] * n_a + [1] * n_b + [2] * n_c)
    a_ids = np.arange(n_a)
    b_ids = n_a + np.arange(n_b)
    c_ids = n_a + n_b + np.arange(n_c)
    edges = [(a, b) for a in a_ids for b in b_ids]
    edges += [(b, int(rng.choice(c_ids))) for b in b_ids]
    g = build_graph(vlabels.size, vlabels, np.asarray(edges))
    q = build_graph(3, np.array([0, 1, 2]), np.array([[0, 1], [1, 2]]))
    return g, q


# ---------------------------------------------------------------------------
# Greedy helper: deduplicated, bit-identical to the old inline rule.
# ---------------------------------------------------------------------------


class TestGreedyHelper:
    def test_bit_identical_to_legacy_inline_rule(self):
        for seed in range(25):
            g = random_labeled_graph(120, 420, 5, seed=seed)
            q = random_walk_query(g, 3 + seed % 6, seed=seed + 100)
            sizes = _label_candidates(g, q).sum(axis=0)
            adj = _host_adjacency(q)
            assert greedy_matching_order(sizes, adj) == _legacy_greedy(
                sizes, adj
            )

    def test_ties_break_to_smallest_vertex_id(self):
        # all-equal sizes, triangle query: deterministic 0,1,2
        q = build_graph(3, np.array([1, 1, 1]),
                        np.array([[0, 1], [1, 2], [0, 2]]))
        order = greedy_matching_order(np.array([4, 4, 4]),
                                      _host_adjacency(q))
        assert order == [0, 1, 2]

    def test_disconnected_query_covers_all_vertices(self):
        q = build_graph(4, np.array([0, 1, 0, 1]), np.array([[0, 1]]))
        order = greedy_matching_order(np.array([2, 3, 4, 5]),
                                      _host_adjacency(q))
        assert sorted(order) == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Order invariance of both searchers.
# ---------------------------------------------------------------------------


class TestOrderInvariance:
    def test_connected_orders_enumerate_identical_sets(self):
        rng = np.random.default_rng(0)
        for seed in range(6):
            g = random_labeled_graph(80, 260, 4, seed=seed)
            q = random_walk_query(g, 5, seed=seed + 50)
            res = ilgf(g, q)
            alive = np.asarray(res.alive)
            cand = np.asarray(res.candidates) & alive[:, None]
            ref = _emb_set(host_dfs_search(g, q, cand))
            assert ref == _emb_set(bfs_join_search(g, q, cand))
            for _ in range(4):
                order = _random_connected_order(q, rng)
                assert ref == _emb_set(
                    host_dfs_search(g, q, cand, order=order)
                ), order
                assert ref == _emb_set(
                    bfs_join_search(g, q, cand, order=order)
                ), order

    def test_arbitrary_permutation_still_exact(self):
        # even a disconnected (worst-case) order must enumerate exactly
        g = random_labeled_graph(60, 200, 3, seed=7)
        q = random_walk_query(g, 4, seed=8)
        cand = _label_candidates(g, q)
        ref = _emb_set(host_dfs_search(g, q, cand))
        worst = list(reversed(greedy_matching_order(
            cand.sum(axis=0), _host_adjacency(q)
        )))
        assert ref == _emb_set(host_dfs_search(g, q, cand, order=worst))
        assert ref == _emb_set(bfs_join_search(g, q, cand, order=worst))

    def test_invalid_order_rejected(self):
        g = random_labeled_graph(30, 80, 3, seed=1)
        q = random_walk_query(g, 4, seed=2)
        cand = _label_candidates(g, q)
        for bad in ([0, 1, 2], [0, 1, 2, 2], [1, 2, 3, 4]):
            with pytest.raises(ValueError):
                host_dfs_search(g, q, cand, order=bad)
            with pytest.raises(ValueError):
                bfs_join_search(g, q, cand, order=bad)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_random_small_graphs(self, seed):
        rng = np.random.default_rng(seed)
        n_v = int(rng.integers(8, 40))
        n_e = int(rng.integers(n_v, 4 * n_v))
        g = random_labeled_graph(n_v, n_e, int(rng.integers(2, 5)),
                                 seed=seed)
        try:
            q = random_walk_query(g, int(rng.integers(3, 6)), seed=seed + 1)
        except ValueError:  # generated graph had no edges
            return
        cand = _label_candidates(g, q)
        ref = _emb_set(host_dfs_search(g, q, cand))
        for _ in range(3):
            order = _random_connected_order(q, rng)
            assert ref == _emb_set(host_dfs_search(g, q, cand, order=order))
            assert ref == _emb_set(bfs_join_search(g, q, cand, order=order))


# ---------------------------------------------------------------------------
# GraphStats: incremental maintenance == scratch rebuild.
# ---------------------------------------------------------------------------


def _assert_stats_equal(a: GraphStats, b: GraphStats):
    np.testing.assert_array_equal(a.universe, b.universe)
    np.testing.assert_array_equal(a.label_hist, b.label_hist)
    np.testing.assert_array_equal(a.deg_sum, b.deg_sum)
    np.testing.assert_array_equal(a.pair_counts, b.pair_counts)
    assert a.n_edges == b.n_edges and a.n_vertices == b.n_vertices


class TestGraphStats:
    @pytest.mark.parametrize("sharded", [False, True])
    def test_incremental_equals_scratch(self, sharded):
        g = random_labeled_graph(100, 360, 5, n_edge_labels=2, seed=3)
        if sharded:
            store = ShardedGraphStore.from_graph(g, n_shards=4)
            store.attach_index(ShardedIncrementalIndex())
        else:
            store = GraphStore.from_graph(g)
            store.attach_index(IncrementalIndex())
        for batch in random_update_batches(g, 6, 40, delete_frac=0.4,
                                           seed=4):
            store.apply(batch)
            _assert_stats_equal(store.index.graph_stats,
                                GraphStats.from_store(store))
            assert store.index.graph_stats.version == store.epoch

    def test_from_graph_matches_from_store(self):
        g = random_labeled_graph(150, 500, 6, seed=5)
        _assert_stats_equal(GraphStats.from_graph(g),
                            GraphStats.from_store(GraphStore.from_graph(g)))

    def test_snapshot_carries_frozen_stats(self):
        g = random_labeled_graph(60, 200, 4, seed=6)
        store = GraphStore.from_graph(g)
        store.attach_index(IncrementalIndex())
        snap = store.snapshot()
        assert snap.index.stats is not None
        frozen = snap.index.stats
        store.add_edges([[0, 1], [2, 3]])
        # the frozen copy must not see the mutation; the live object must
        assert frozen.version != store.epoch
        assert store.index.graph_stats.version == store.epoch

    def test_bucket_drift_gating(self):
        g = random_labeled_graph(80, 300, 4, seed=7)
        store = GraphStore.from_graph(g)
        store.attach_index(IncrementalIndex())
        gs = store.index.graph_stats
        gs.rebucket_frac = 0.0  # every applied record forces a new bucket
        b0 = gs.bucket
        store.add_edges([[0, 50]])
        assert gs.bucket == b0 + 1
        gs.rebucket_frac = 0.5  # small drift no longer re-buckets
        b1 = gs.bucket
        store.add_edges([[1, 51]])
        assert gs.bucket == b1

    def test_query_view_bounds_and_absent_labels(self):
        g = random_labeled_graph(90, 300, 4, seed=8)
        stats = GraphStats.from_graph(g)
        labels = np.array([0, 1, 99])  # 99 not in the universe
        hist_q, prob_q = stats.query_view(labels)
        assert hist_q[2] == 0.0
        assert (prob_q >= 0).all() and (prob_q <= 1).all()
        assert (prob_q[2] == 0).all() and (prob_q[:, 2] == 0).all()


# ---------------------------------------------------------------------------
# Planner: orders, fingerprints, cost model.
# ---------------------------------------------------------------------------


class TestQueryPlanner:
    def test_statsless_planner_is_bit_identical_to_greedy(self):
        planner = QueryPlanner(None)
        for seed in range(10):
            g = random_labeled_graph(90, 300, 4, seed=seed)
            q = random_walk_query(g, 5, seed=seed + 30)
            sizes = _label_candidates(g, q).sum(axis=0)
            plan = planner.plan(q, candidate_counts=sizes)
            assert plan.source == "greedy"
            assert list(plan.order) == _legacy_greedy(
                sizes, _host_adjacency(q)
            )
        assert len(planner.cache) == 0  # greedy plans are never cached

    def test_plans_are_valid_connected_orders(self):
        g = random_labeled_graph(150, 600, 5, seed=9)
        planner = QueryPlanner(GraphStats.from_graph(g))
        for seed in range(8):
            q = random_walk_query(g, 6, seed=seed)
            plan = planner.plan(q)
            assert sorted(plan.order) == list(range(q.n_vertices))
            adj = _host_adjacency(q)
            for t in range(1, len(plan.order)):
                u = plan.order[t]
                assert any(w in adj.get(u, {}) for w in plan.order[:t])

    def test_cost_model_beats_greedy_on_skewed_labels(self):
        g, q = _skewed_graph_and_query()
        stats = GraphStats.from_graph(g)
        planner = QueryPlanner(stats)
        cand = _label_candidates(g, q)
        sizes = cand.sum(axis=0).astype(float)
        plan = planner.plan(q, candidate_counts=sizes)
        adj = _host_adjacency(q)
        greedy = _legacy_greedy(sizes, adj)
        hist_q, prob_q, lab_ix = planner._query_stats(q, stats)
        cost_planned, _, _ = planner._estimate(plan.order, adj, sizes,
                                               (prob_q, lab_ix))
        cost_greedy, _, _ = planner._estimate(greedy, adj, sizes,
                                              (prob_q, lab_ix))
        assert list(plan.order) != greedy
        assert cost_planned < cost_greedy
        # planned order starts from the selective (C) side, not the A hub
        assert plan.order[0] == 2
        # and both orders enumerate the identical embedding set
        ref = _emb_set(bfs_join_search(g, q, cand, order=greedy))
        assert ref == _emb_set(bfs_join_search(g, q, cand,
                                               order=list(plan.order)))
        assert len(ref) > 0

    def test_fingerprint_invariant_under_renumbering(self):
        # a labeled path is separated by refinement: renumbering it keeps
        # the canonical form (and thus the fingerprint) identical
        q1 = build_graph(3, np.array([0, 1, 2]), np.array([[0, 1], [1, 2]]))
        q2 = build_graph(3, np.array([2, 1, 0]), np.array([[2, 1], [1, 0]]))
        assert query_fingerprint(q1) == query_fingerprint(q2)
        _, f1 = canonical_form(q1)
        _, f2 = canonical_form(q2)
        assert f1 == f2

    def test_cached_plan_maps_to_renumbered_query(self):
        g, q1 = _skewed_graph_and_query()
        q2 = build_graph(3, np.array([2, 1, 0]), np.array([[2, 1], [1, 0]]))
        planner = QueryPlanner(GraphStats.from_graph(g))
        planner.plan(q1)
        plan2 = planner.plan(q2)
        assert plan2.source == "cache"
        assert sorted(plan2.order) == [0, 1, 2]
        # q2's C-labeled vertex is vertex 0; the mapped plan starts there
        assert plan2.order[0] == 0

    def test_explain_mentions_steps_and_source(self):
        g, q = _skewed_graph_and_query()
        plan = QueryPlanner(GraphStats.from_graph(g)).plan(q)
        text = plan.explain()
        assert "Plan[stats]" in text and "est_cost" in text
        assert len(text.splitlines()) == 2 + q.n_vertices


# ---------------------------------------------------------------------------
# Plan cache: hits, LRU, epoch/bucket invalidation.
# ---------------------------------------------------------------------------


class TestPlanCache:
    def test_repeat_queries_hit(self):
        g = random_labeled_graph(120, 420, 5, seed=10)
        planner = QueryPlanner(GraphStats.from_graph(g))
        q = random_walk_query(g, 5, seed=11)
        assert planner.plan(q).source == "stats"
        for _ in range(5):
            assert planner.plan(q).source == "cache"
        assert planner.cache.hits == 5 and planner.cache.misses == 1
        assert planner.cache.hit_rate == 5 / 6

    def test_mutation_epochs_invalidate_via_bucket(self):
        g = random_labeled_graph(100, 360, 5, seed=12)
        store = GraphStore.from_graph(g)
        store.attach_index(IncrementalIndex())
        store.index.graph_stats.rebucket_frac = 0.0  # every batch re-buckets
        planner = QueryPlanner.for_data(store)
        q = random_walk_query(g, 5, seed=13)
        assert planner.plan(q).source == "stats"
        assert planner.plan(q).source == "cache"
        store.add_edges([[0, 60]])  # bucket moves with the mutation epoch
        assert planner.plan(q).source == "stats"  # stale plan not served
        assert planner.cache.invalidated >= 1
        assert planner.plan(q).source == "cache"

    def test_small_drift_keeps_cache_warm(self):
        g = random_labeled_graph(200, 800, 5, seed=14)
        store = GraphStore.from_graph(g)
        store.attach_index(IncrementalIndex())  # default rebucket_frac
        planner = QueryPlanner.for_data(store)
        q = random_walk_query(g, 5, seed=15)
        planner.plan(q)
        store.add_edges([[0, 100]])  # tiny drift: far below the threshold
        assert planner.plan(q).source == "cache"

    def test_lru_eviction(self):
        cache = PlanCache(max_entries=2)
        g = random_labeled_graph(100, 360, 6, seed=16)
        planner = QueryPlanner(GraphStats.from_graph(g), cache=cache)
        queries = [random_walk_query(g, 5, seed=20 + i) for i in range(3)]
        fps = {query_fingerprint(q) for q in queries}
        if len(fps) < 3:  # pragma: no cover - astronomically unlikely
            pytest.skip("fingerprint collision in random queries")
        for q in queries:
            planner.plan(q)
        assert len(cache) == 2 and cache.evictions == 1
        assert planner.plan(queries[0]).source == "stats"  # evicted


# ---------------------------------------------------------------------------
# Integration: engines + service plan before enumeration, results unchanged.
# ---------------------------------------------------------------------------


class TestIntegration:
    def test_engine_with_planner_matches_without(self):
        g = random_labeled_graph(250, 900, 6, seed=17)
        store = GraphStore.from_graph(g)
        store.attach_index(IncrementalIndex())
        planner = QueryPlanner.for_data(store)
        on = SubgraphQueryEngine(store, planner=planner)
        off = SubgraphQueryEngine(store)
        dfs = SubgraphQueryEngine(store, planner=planner, searcher="dfs")
        for seed in range(5):
            q = random_walk_query(g, 5, seed=30 + seed)
            e_on, s_on = on.query(q)
            e_off, _ = off.query(q)
            e_dfs, _ = dfs.query(q)
            assert _emb_set(e_on) == _emb_set(e_off) == _emb_set(e_dfs)
            assert s_on.extras["plan"]["source"] in ("stats", "cache")

    def test_all_pruned_query_still_records_plan_entry(self):
        # a query whose label is absent prunes to zero survivors; the
        # planner contract (extras["plan"] always present) must hold
        g = random_labeled_graph(100, 300, 4, seed=22)
        from repro.graphs.csr import build_graph
        q = build_graph(2, np.array([77, 78]), np.array([[0, 1]]))
        eng = SubgraphQueryEngine(g, planner=QueryPlanner.for_data(g))
        emb, stats = eng.query(q)
        assert emb.shape == (0, 2)
        assert stats.extras["plan"]["source"] == "skipped"
        assert stats.extras["plan"]["order"] == ()

    def test_batch_engine_plans_and_matches_sequential(self):
        g = random_labeled_graph(250, 900, 6, seed=18)
        planner = QueryPlanner.for_data(g)
        queries = [random_walk_query(g, 4 + i % 3, seed=40 + i)
                   for i in range(6)]
        batched = BatchQueryEngine(g, planner=planner).query_batch(queries)
        seq = SubgraphQueryEngine(g)
        for q, (emb, stats) in zip(queries, batched):
            ref, _ = seq.query(q)
            assert _emb_set(emb) == _emb_set(ref)
            assert "plan" in stats.extras

    def test_service_shares_cache_across_ticks_and_slots(self):
        g = random_labeled_graph(200, 700, 6, seed=19)
        store = GraphStore.from_graph(g, degree_cap=64)
        store.attach_index(IncrementalIndex())
        svc_on = GraphQueryService(store, GraphServiceConfig(
            max_slots=3, max_query_vertices=8, max_query_labels=8,
            plan_queries=True))
        svc_off = GraphQueryService(store, GraphServiceConfig(
            max_slots=3, max_query_vertices=8, max_query_labels=8))
        queries = [random_walk_query(g, 5, seed=50 + i) for i in range(4)]
        rids_on = [svc_on.submit(q) for q in queries for _ in range(3)]
        done_on = {rid: emb for rid, emb, _ in svc_on.run_to_completion()}
        assert set(done_on) == set(rids_on)
        rids_off = [svc_off.submit(q) for q in queries]
        done_off = {rid: emb for rid, emb, _ in svc_off.run_to_completion()}
        for i, q in enumerate(queries):
            ref = _emb_set(done_off[rids_off[i]])
            for k in range(3):
                assert _emb_set(done_on[rids_on[3 * i + k]]) == ref
        cache = svc_on.planner.cache
        assert cache.misses <= len(queries)
        assert cache.hits >= 2 * len(queries)

    def test_service_planning_survives_mutation_epochs(self):
        g = random_labeled_graph(200, 700, 6, seed=21)
        store = GraphStore.from_graph(g, degree_cap=64)
        store.attach_index(IncrementalIndex())
        svc = GraphQueryService(store, GraphServiceConfig(
            max_slots=2, max_query_vertices=8, max_query_labels=8,
            plan_queries=True))
        queries = [random_walk_query(g, 5, seed=60 + i) for i in range(4)]
        rids = [svc.submit(q) for q in queries[:2]]
        done = svc.tick()
        svc.add_edges([[0, 150], [1, 151]])
        rids += [svc.submit(q) for q in queries[2:]]
        done += svc.run_to_completion()
        assert {rid for rid, _, _ in done} == set(rids)
        # pinned-epoch results still match a sequential engine per epoch
        for rid, emb, stats in done:
            q = queries[rids.index(rid)]
            ref, _ = SubgraphQueryEngine(store).query(q)
            if stats.extras["service"]["epoch"] == store.epoch:
                assert _emb_set(emb) == _emb_set(ref)
