"""Admission control, drain accounting, and replica routing
(serve/graph_service.py, serve/replicas.py).

Three contracts:

* **Admission is bounded and typed** — ``submit`` past ``max_queue_depth``
  or a tenant's quota raises ``AdmissionRejected`` (reason, rid, tenant)
  and records the rejection (list + counter); it never silently drops or
  silently grows the queue.  Queued requests whose deadline lapses before
  admission are expired with a report, admitted ones run
  priority-desc / deadline-asc / FIFO.
* **Nothing leaks through shutdown** — every submitted rid comes back as
  finished or cancelled even when the drain budget exhausts with requests
  still in flight (satellite regression: those used to vanish), and
  ``run_to_completion`` signals an incomplete drain with ``DrainTimeout``
  carrying the partial results instead of returning them as if complete.
* **The d_max soundness guard survives ``python -O``** — the degree
  invariant is a real RuntimeError, not an assert (satellite regression:
  it used to vanish under optimized bytecode).
"""

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import repro
from repro.core.engine import SubgraphQueryEngine
from repro.core.incremental import IncrementalIndex
from repro.graphs import random_labeled_graph, random_walk_query
from repro.graphs.store import GraphStore
from repro.serve import (
    AdmissionRejected,
    DrainTimeout,
    GraphQueryService,
    GraphServiceConfig,
    ReplicatedGraphService,
)

_SRC = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))


def _eset(emb):
    emb = np.asarray(emb)
    if emb.size == 0:
        return set()
    return set(map(tuple, emb.reshape(emb.shape[0], -1).tolist()))


def _service(g_or_store, **kw):
    cfg = dict(max_slots=1, max_query_vertices=8, max_query_labels=8)
    cfg.update(kw)
    return GraphQueryService(g_or_store, GraphServiceConfig(**cfg))


@pytest.fixture(scope="module")
def graph():
    return random_labeled_graph(60, 150, 4, seed=3)


@pytest.fixture(scope="module")
def queries(graph):
    return [random_walk_query(graph, 4, seed=40 + i) for i in range(8)]


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_queue_full_rejects_typed(self, graph, queries):
        svc = _service(graph, max_queue_depth=2)
        svc.submit(queries[0])
        svc.submit(queries[1])
        with pytest.raises(AdmissionRejected) as exc:
            svc.submit(queries[2])
        assert exc.value.reason == "queue_full"
        assert exc.value.tenant == "default"
        # the rejection is recorded, not just raised
        assert svc.rejections[-1].reason == "queue_full"
        assert svc.rejections[-1].rid == exc.value.rid
        fam = svc.metrics_snapshot()["repro_service_rejected_total"]
        assert fam["series"][(("reason", "queue_full"),)] == 1
        # the queue did NOT grow past the bound
        assert len(svc.queue) == 2
        # draining frees capacity: the same query is admissible again
        svc.run_to_completion()
        svc.submit(queries[2])

    def test_tenant_quota_isolates_tenants(self, graph, queries):
        svc = _service(graph, tenant_quota=1)
        svc.submit(queries[0], tenant="a")
        with pytest.raises(AdmissionRejected) as exc:
            svc.submit(queries[1], tenant="a")
        assert exc.value.reason == "tenant_quota"
        assert exc.value.tenant == "a"
        # another tenant's slice is untouched by a's backpressure
        svc.submit(queries[1], tenant="b")
        done = svc.run_to_completion()
        tenants = {s.extras["service"]["tenant"] for _, _, s in done}
        assert tenants == {"a", "b"}

    def test_quota_counts_inflight_requests(self, graph, queries):
        svc = _service(graph, tenant_quota=1, max_slots=2)
        svc.submit(queries[0], tenant="a")
        svc.tick()  # admitted: queued count is 0, active count is 1
        if svc.n_active:
            with pytest.raises(AdmissionRejected, match="tenant"):
                svc.submit(queries[1], tenant="a")
        svc.run_to_completion()

    def test_unbounded_when_disabled(self, graph, queries):
        svc = _service(graph, max_queue_depth=None)
        for q in queries:
            svc.submit(q)
        assert len(svc.queue) == len(queries)
        svc.run_to_completion()


# ---------------------------------------------------------------------------
# priority / deadline scheduling
# ---------------------------------------------------------------------------


class TestScheduling:
    def test_priority_order(self, graph, queries):
        svc = _service(graph, max_slots=1)
        rlo = svc.submit(queries[0], priority=0)
        rhi = svc.submit(queries[1], priority=5)
        order = [r for r, _, _ in svc.run_to_completion()]
        assert order.index(rhi) < order.index(rlo)

    def test_deadline_breaks_priority_ties(self, graph, queries):
        svc = _service(graph, max_slots=1)
        r_late = svc.submit(queries[0], deadline_seconds=60.0)
        r_soon = svc.submit(queries[1], deadline_seconds=5.0)
        order = [r for r, _, _ in svc.run_to_completion()]
        assert order.index(r_soon) < order.index(r_late)

    def test_lapsed_deadline_expires_before_admission(self, graph, queries):
        svc = _service(graph, max_slots=1)
        rex = svc.submit(queries[0], deadline_seconds=-1.0)
        rok = svc.submit(queries[1])
        done = [r for r, _, _ in svc.run_to_completion()]
        assert done == [rok]
        assert [c.rid for c in svc.expired] == [rex]
        assert "deadline" in svc.expired[0].reason
        snap = svc.metrics_snapshot()
        miss = snap["repro_service_deadline_missed_total"]
        assert sum(miss["series"].values()) == 1
        reqs = snap["repro_service_requests_total"]["series"]
        assert reqs[(("status", "expired"),)] == 1

    def test_completed_late_flags_deadline_missed(self, graph, queries):
        svc = _service(graph, max_slots=1)
        rid = svc.submit(queries[0], deadline_seconds=30.0)
        svc.tick()  # admit while the deadline is comfortably in the future
        req = next(r for r in svc.active if r is not None and r.rid == rid)
        req.deadline = time.perf_counter() - 1.0  # lapse it mid-flight
        done = {r: s for r, _, s in svc.run_to_completion()}
        assert done[rid].extras["service"]["deadline_missed"] is True

    def test_report_carries_admission_fields(self, graph, queries):
        svc = _service(graph)
        svc.submit(queries[0], tenant="t9", priority=3)
        (_, _, stats), = svc.run_to_completion()
        rep = stats.extras["service"]
        assert rep["tenant"] == "t9"
        assert rep["priority"] == 3
        assert rep["deadline_missed"] is False


# ---------------------------------------------------------------------------
# drain accounting (shutdown leak + DrainTimeout)
# ---------------------------------------------------------------------------


class TestDrainAccounting:
    def test_exhausted_drain_cancels_inflight(self, graph, queries):
        """Regression: drain=True with an exhausted tick budget used to
        return with in-flight requests neither finished nor cancelled."""
        svc = _service(graph, max_slots=2)
        rids = [svc.submit(q) for q in queries[:4]]
        svc.tick()
        finished, cancelled = svc.shutdown(drain=True, max_ticks=0)
        fin = {r for r, _, _ in finished}
        can = {c.rid for c in cancelled}
        assert fin | can == set(rids), "requests leaked through shutdown"
        reasons = {c.reason for c in cancelled}
        assert "shutdown drain exhausted" in reasons
        assert svc.n_active == 0 and not svc.queue

    def test_run_to_completion_raises_drain_timeout(self, graph, queries):
        svc = _service(graph, max_slots=1)
        rids = [svc.submit(q) for q in queries[:3]]
        with pytest.raises(DrainTimeout) as exc:
            svc.run_to_completion(max_ticks=1)
        # partial results ride on the exception, not dropped
        assert isinstance(exc.value.finished, list)
        assert {r for r, _, _ in exc.value.finished} <= set(rids)
        # the service is still live: draining afterwards completes the rest
        rest = svc.run_to_completion()
        got = {r for r, _, _ in exc.value.finished} | {r for r, _, _ in rest}
        assert got == set(rids)


# ---------------------------------------------------------------------------
# d_max invariant: a real error, not an assert
# ---------------------------------------------------------------------------


class TestDegreeInvariant:
    def test_widened_cap_raises_runtime_error(self, graph):
        store = GraphStore.from_graph(graph)
        svc = _service(store)
        # widen the cap behind the service's back, then blow past d_max
        store.degree_cap = svc.d_max + 64
        hub = int(np.argmax(store.degrees()))
        extra = [v for v in range(store.n_vertices)
                 if v != hub and not store.has_edge(hub, v)]
        need = svc.d_max - int(store.degrees()[hub]) + 1
        with pytest.raises(RuntimeError, match="static d_max"):
            svc.add_edges([[hub, v] for v in extra[:need]])

    def test_invariant_survives_python_O(self):
        """The old ``assert`` vanished under ``python -O``; the RuntimeError
        must not.  Drives the same scenario in an optimized subprocess."""
        prog = textwrap.dedent("""
            import numpy as np
            from repro.graphs import random_labeled_graph
            from repro.graphs.store import GraphStore
            from repro.serve import GraphQueryService, GraphServiceConfig

            assert False is True or True  # -O proof: asserts are stripped
            g = random_labeled_graph(60, 150, 4, seed=3)
            store = GraphStore.from_graph(g)
            svc = GraphQueryService(store, GraphServiceConfig(
                max_slots=1, max_query_vertices=8, max_query_labels=8))
            store.degree_cap = svc.d_max + 64
            hub = int(np.argmax(store.degrees()))
            extra = [v for v in range(store.n_vertices)
                     if v != hub and not store.has_edge(hub, v)]
            need = svc.d_max - int(store.degrees()[hub]) + 1
            try:
                svc.add_edges([[hub, v] for v in extra[:need]])
            except RuntimeError as err:
                assert_ok = "static d_max" in str(err)
                print("GUARD_HELD" if assert_ok else f"WRONG_ERROR {err}")
            else:
                print("GUARD_VANISHED")
        """)
        out = subprocess.run(
            [sys.executable, "-O", "-c", prog],
            capture_output=True, text=True, timeout=300,
            env={**os.environ, "PYTHONPATH": _SRC},
        )
        assert out.returncode == 0, out.stderr
        assert "GUARD_HELD" in out.stdout, (out.stdout, out.stderr)


# ---------------------------------------------------------------------------
# replica routing
# ---------------------------------------------------------------------------


class TestReplicas:
    def _router(self, graph, n_replicas=3, **kw):
        store = GraphStore.from_graph(graph, degree_cap=64)
        store.attach_index(IncrementalIndex())
        cfg = dict(max_slots=2, max_query_vertices=8, max_query_labels=8)
        cfg.update(kw)
        return store, ReplicatedGraphService(
            store, GraphServiceConfig(**cfg), n_replicas=n_replicas)

    def test_requires_mutable_store(self, graph):
        with pytest.raises(TypeError, match="BaseGraphStore"):
            ReplicatedGraphService(graph)

    def test_submit_spreads_load_and_rids_are_global(self, graph, queries):
        store, rs = self._router(graph)
        rids = [rs.submit(q) for q in queries[:6]]
        assert len(set(rids)) == 6
        loaded = sum(1 for r in rs.replicas if r.queue or r.n_active)
        assert loaded == 3, "least-loaded routing left replicas idle"
        done = {r for r, _, _ in rs.run_to_completion()}
        assert done == set(rids)
        rs.shutdown()

    def test_results_match_single_service_with_mutations(self, graph,
                                                         queries):
        store, rs = self._router(graph)
        gr = [rs.submit(q) for q in queries[:6]]
        done = dict()
        for r, e, s in rs.tick():
            done[r] = (e, s)
        rs.add_edges([[i, (i + 13) % 60] for i in range(0, 30, 3)])
        for r, e, s in rs.run_to_completion():
            done[r] = (e, s)
        assert sorted(done) == sorted(gr)
        latest = store.snapshot().graph
        for rid, q in zip(gr, queries[:6]):
            emb, st = done[rid]
            if st.extras["service"]["epoch"] == store.epoch:
                ref, _ = SubgraphQueryEngine(latest).query(q)
                assert _eset(emb) == _eset(ref)
        rs.shutdown()

    def test_read_replicas_reject_direct_mutation(self, graph):
        store, rs = self._router(graph)
        with pytest.raises(RuntimeError, match="read replica"):
            rs.replicas[1].add_edges([[0, 1]])
        # the router's write path works and bumps the shared epoch
        e0 = rs.epoch
        rs.add_edges([[0, 7]])
        assert rs.epoch == e0 + 1
        assert all(r.store.epoch == rs.epoch for r in rs.replicas)
        rs.shutdown()

    def test_inflight_queries_pin_epochs_across_replicas(self, graph,
                                                         queries):
        """A query admitted on ANY replica pins its epoch on the SHARED
        store — the writer's mutations must not tear it down."""
        store, rs = self._router(graph, max_slots=1)
        for q in queries[:3]:
            rs.submit(q)
        rs.tick()  # admits one per replica at epoch 0
        pinned = store.epoch
        rs.add_edges([[1, 44]])
        # the old epoch stays cached while any replica still holds a pin
        assert any(
            pinned in r._epochs for r in rs.replicas
        ) or all(r.n_active == 0 for r in rs.replicas)
        rs.run_to_completion()
        # after the drain only the latest epoch may remain cached
        for r in rs.replicas:
            assert set(r._epochs) <= {store.epoch}
        rs.shutdown()

    def test_shutdown_translates_rids(self, graph, queries):
        store, rs = self._router(graph, n_replicas=2, max_slots=1)
        rids = [rs.submit(q) for q in queries[:4]]
        first = rs.tick()
        finished, cancelled = rs.shutdown(drain=False)
        fin = {r for r, _, _ in first + finished}
        can = {c.rid for c in cancelled}
        assert fin | can == set(rids), "router leaked or mistranslated rids"

    def test_single_replica_degenerates_to_service(self, graph, queries):
        store, rs = self._router(graph, n_replicas=1)
        rid = rs.submit(queries[0])
        done = {r for r, _, _ in rs.run_to_completion()}
        assert done == {rid}
        assert rs.writer is rs.replicas[0]
        rs.shutdown()

    def test_metrics_keyed_per_replica(self, graph, queries):
        store, rs = self._router(graph, n_replicas=2)
        rs.submit(queries[0])
        rs.run_to_completion()
        snap = rs.metrics_snapshot()
        assert set(snap) == {"replica_0", "replica_1"}
        assert "repro_service_requests_total" in snap["replica_0"]
        rs.shutdown()
