"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train-gradient step + one decode step on CPU; asserts shapes
and finiteness.  The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config
from repro.configs.registry import frontend_len
from repro.models import model as M

BATCH, SEQ = 2, 32

# Runtime audit (ISSUE 5): the largest reduced configs dominate the fast
# tier (20-35s each on CI-class CPUs) while exercising the same model code
# paths as the small members of their families — keep a representative
# small arch per family fast, push the giants to the slow tier.
_SLOW_ARCHS = {
    "deepseek-v3-671b",       # MLA covered fast by minicpm3-4b
    "hymba-1.5b",
    "seamless-m4t-large-v2",
    "qwen3-moe-30b-a3b",      # MoE paths covered fast by test_optimizations
}
_ARCH_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
    for a in ARCHITECTURES
]


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    tokens = jax.random.randint(ks[0], (BATCH, SEQ), 0, cfg.vocab)
    labels = jax.random.randint(ks[1], (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend != "none":
        fl = frontend_len(cfg, SEQ)
        batch["frontend"] = jax.random.normal(
            ks[2], (BATCH, fl, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, specs = M.init_params(key, cfg)
    # spec tree mirrors params structure
    assert set(params.keys()) == set(specs.keys())
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    logits, aux = M.forward(params, cfg, batch["tokens"],
                            frontend=batch.get("frontend"))
    vp = M.vocab_padded(cfg)
    assert logits.shape == (BATCH, SEQ, vp)
    assert np.isfinite(np.asarray(logits[..., : cfg.vocab])).all(), arch

    loss, metrics = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"

    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat), arch
    gnorm = sum(float(jnp.sum(g * g)) for g in flat) ** 0.5
    assert gnorm > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_decode_matches_prefill(arch):
    """Teacher-forced decode step logits == forward logits (last position)."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params, _ = M.init_params(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (BATCH, 8), 0, cfg.vocab)
    frontend = None
    if cfg.frontend != "none":
        fl = frontend_len(cfg, 8)
        frontend = jax.random.normal(
            jax.random.PRNGKey(3), (BATCH, fl, cfg.d_model), jnp.float32
        )

    if cfg.family == "vlm":
        full_logits, _ = M.forward(params, cfg, tokens, frontend=frontend)
        pytest.skip("vlm decode covered by dryrun (prefix cache semantics)")
    full_logits, _ = M.forward(params, cfg, tokens, frontend=frontend)

    cache, _ = M.init_cache(cfg, BATCH, 16, jnp.float32,
                            enc_memory_len=frontend.shape[1] if frontend is not None and cfg.n_encoder_layers else 0)
    if cfg.n_encoder_layers:
        cache = M.prefill_encoder(params, cfg, frontend, cache)
    logits_steps = []
    for t in range(8):
        lg, cache = M.decode_step(
            params, cfg, cache, tokens[:, t : t + 1], jnp.asarray(t, jnp.int32)
        )
        logits_steps.append(lg[:, 0])
    dec = jnp.stack(logits_steps, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec[..., : cfg.vocab]),
        np.asarray(full_logits[..., : cfg.vocab]),
        rtol=2e-3, atol=2e-3,
    )


def test_active_param_accounting():
    cfg = get_config("deepseek-v3-671b")
    total = cfg.total_params
    active = cfg.active_params_per_token
    assert 500e9 < total < 900e9, f"deepseek total {total/1e9:.0f}B off"
    assert 25e9 < active < 60e9, f"deepseek active {active/1e9:.0f}B off"
    g8 = get_config("granite-3-8b")
    assert 6e9 < g8.total_params < 11e9
