"""Training/serving substrate: optimizer, checkpoint restart, data pipeline
determinism, trainer loss-goes-down, serve engine, grad compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import DataState, GraphPatternFilter, SyntheticLMDataset
from repro.models import model as M
from repro.optim import adamw_init, adamw_update
from repro.optim.grad_utils import (
    clip_by_global_norm,
    compress_int8,
    decompress_int8,
    global_norm,
)
from repro.train import Trainer, TrainerConfig


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = adamw_update(
                params, grads, state, lr=5e-2, weight_decay=0.0
            )
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_factored_matches_full_direction(self):
        k = jax.random.PRNGKey(0)
        w = jax.random.normal(k, (256, 256))
        params = {"w": w}
        grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (256, 256))}
        s_full = adamw_init(params, factored=False)
        s_fact = adamw_init(params, factored=True)
        p1, _ = adamw_update(params, grads, s_full, lr=1e-2)
        p2, _ = adamw_update(params, grads, s_fact, lr=1e-2)
        # same sign of update on most coordinates (factored is approximate)
        d1 = np.asarray(p1["w"] - w).ravel()
        d2 = np.asarray(p2["w"] - w).ravel()
        agree = (np.sign(d1) == np.sign(d2)).mean()
        assert agree > 0.95, agree

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) > 1.0
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


class TestGradCompression:
    def test_int8_roundtrip_error(self):
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 0.01}
        q, s = compress_int8(tree)
        assert q["w"].dtype == jnp.int8
        back = decompress_int8(q, s, tree)
        rel = float(
            jnp.linalg.norm(back["w"] - tree["w"]) / jnp.linalg.norm(tree["w"])
        )
        assert rel < 1e-2, rel


class TestCheckpoint:
    def test_atomic_save_restore_roundtrip(self):
        with tempfile.TemporaryDirectory() as td:
            tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
            save_checkpoint(td, 7, tree, extra={"note": "x"})
            assert latest_step(td) == 7
            mgr = CheckpointManager(td, async_write=False)
            step, restored, extra = mgr.restore_latest(tree)
            assert step == 7 and extra["note"] == "x"
            np.testing.assert_array_equal(
                np.asarray(restored["a"]), np.arange(10.0)
            )

    def test_stale_tmp_cleaned(self):
        with tempfile.TemporaryDirectory() as td:
            os.makedirs(os.path.join(td, "step_000000005.tmp"))
            save_checkpoint(td, 3, {"a": jnp.zeros(2)})
            assert latest_step(td) == 3
            assert not os.path.exists(os.path.join(td, "step_000000005.tmp"))

    def test_keep_last_k(self):
        with tempfile.TemporaryDirectory() as td:
            mgr = CheckpointManager(td, keep=2, async_write=False)
            for s in (1, 2, 3, 4):
                mgr.save(s, {"a": jnp.zeros(1)})
            steps = sorted(
                int(n[5:]) for n in os.listdir(td) if n.startswith("step_")
            )
            assert steps == [3, 4]


class TestData:
    def test_deterministic_and_resumable(self):
        ds = SyntheticLMDataset(1000, 16, 4, seed=3)
        b1 = ds.batch_at(5)
        b2 = ds.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    def test_graph_pattern_filter(self):
        from repro.graphs import random_labeled_graph, random_walk_query

        g = random_labeled_graph(60, 150, 4, seed=1)
        q = random_walk_query(g, 3, seed=2)
        filt = GraphPatternFilter(q)
        assert filt.matches(g)  # query extracted from g must match g
        # a graph with disjoint labels cannot match
        g2 = random_labeled_graph(40, 80, 3, seed=9)
        import numpy as _np

        from repro.graphs.csr import Graph
        import jax.numpy as _jnp

        g2_shift = Graph(
            vlabels=g2.vlabels + 1000, src=g2.src, dst=g2.dst,
            elabels=g2.elabels,
        )
        assert not filt.matches(g2_shift)


class TestTrainer:
    def _tiny(self):
        cfg = get_config("granite-3-2b").reduced()
        return cfg

    def test_loss_decreases(self):
        cfg = self._tiny()
        tcfg = TrainerConfig(steps=30, lr=3e-3, warmup=3, log_every=10)
        tr = Trainer(cfg, tcfg, global_batch=4, seq_len=32)
        _, _, hist = tr.run()
        first = hist[0][1]["loss"]
        last = hist[-1][1]["loss"]
        assert last < first, f"loss did not decrease: {first} -> {last}"

    def test_restart_resume_exact(self):
        cfg = self._tiny()
        with tempfile.TemporaryDirectory() as td:
            tc = dict(lr=1e-3, warmup=2, checkpoint_dir=td,
                      checkpoint_every=5, log_every=1)
            # run 10 steps straight
            tr_a = Trainer(cfg, TrainerConfig(steps=10, **tc),
                           global_batch=2, seq_len=16, seed=1)
            pa, _, _ = tr_a.run(key=jax.random.PRNGKey(7))
        with tempfile.TemporaryDirectory() as td:
            tc["checkpoint_dir"] = td

            # same 10-step job, crashed mid-flight after the step-5 commit
            class _Crash(RuntimeError):
                pass

            def crash_after_5(step, _):
                if step > 5:
                    raise _Crash

            tr_b = Trainer(cfg, TrainerConfig(steps=10, **tc),
                           global_batch=2, seq_len=16, seed=1)
            try:
                tr_b.run(key=jax.random.PRNGKey(7), on_metrics=crash_after_5)
            except _Crash:
                pass
            tr_b.ckpt.wait()
            # a NEW trainer resumes the same job and finishes it
            tr_c = Trainer(cfg, TrainerConfig(steps=10, **tc),
                           global_batch=2, seq_len=16, seed=1)
            pc, _, _ = tr_c.run(key=jax.random.PRNGKey(7))
        for a, c in zip(jax.tree.leaves(pa), jax.tree.leaves(pc)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(c, np.float32),
                rtol=2e-4, atol=2e-4,
            )


class TestServe:
    def test_continuous_batching_greedy(self):
        cfg = get_config("granite-3-2b").reduced()
        params, _ = M.init_params(jax.random.PRNGKey(0), cfg)
        from repro.serve import ServeConfig, ServeEngine

        eng = ServeEngine(params, cfg, ServeConfig(max_batch=2, max_len=64,
                                                   eos_token=-1))
        r1 = eng.submit(np.array([1, 2, 3]), max_new=4)
        r2 = eng.submit(np.array([4, 5]), max_new=4)
        r3 = eng.submit(np.array([6]), max_new=3)  # queued until a slot frees
        done = eng.run_to_completion()
        rids = {rid for rid, _ in done}
        assert rids == {r1, r2, r3}
        for _, toks in done:
            assert 3 <= len(toks) <= 4
            assert all(0 <= t < cfg.vocab for t in toks)
