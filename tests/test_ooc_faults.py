"""Fault injection for the out-of-core disk tier (graphs/ooc.py).

The contract under test (DESIGN.md §14, "fail closed"): any mismatch
between the bytes on disk and what the manifest/header promises — a
truncated chunk, a corrupted header, a manifest entry pointing nowhere, or
an I/O error in the middle of a prefiltered fetch — surfaces as the typed
``ChunkIOError``, never as a silently wrong edge set.  And the failure is
*contained*: epoch pins taken on the way in are released, the service frees
the slot, and once the fault clears the same store answers the same query
with the same rows.

Every scenario corrupts a real chunk directory on disk (built small, a few
records per chunk, so each file is individually addressable) and then
drives a real query through the engine or the service — the error must
travel the whole prefilter → manifest → chunk-read path, not be synthesized
at the io layer.
"""

import json
import os
import shutil

import numpy as np
import pytest

import repro.graphs.io as gio
import repro.graphs.ooc as ooc_mod
from repro.core.engine import SubgraphQueryEngine
from repro.graphs import (
    ChunkIOError,
    OutOfCoreGraphStore,
    random_labeled_graph,
    random_walk_query,
)
from repro.serve import GraphQueryService, GraphServiceConfig

_V, _E = 36, 90


def _mk(tmp_path, **kwargs):
    """A persisted store + a query with a non-empty answer."""
    g = random_labeled_graph(_V, _E, 3, n_edge_labels=2, seed=0)
    q = random_walk_query(g, 4, seed=1)
    store = OutOfCoreGraphStore.from_graph(
        g, storage_dir=str(tmp_path / "store"), chunk_edges=16, **kwargs
    )
    assert store.n_chunks >= 3  # faults must be per-file addressable
    return g, q, store


def _chunk_files(store) -> list[str]:
    gen = store._base
    return [os.path.join(gen.path, e["file"]) for e in gen.entries]


def _cold(store) -> None:
    """Evict the generation from the LRU so the next fetch hits disk."""
    store.cache.drop_generation(store.generation)


def _backup(store, tmp_path) -> str:
    bak = str(tmp_path / "backup-gen")
    shutil.copytree(store._base.path, bak)
    return bak


def _restore(store, bak: str) -> None:
    shutil.rmtree(store._base.path)
    shutil.copytree(bak, store._base.path)
    _cold(store)


# ---------------------------------------------------------------------------
# corrupted bytes on disk → typed error, recoverable after repair
# ---------------------------------------------------------------------------


def test_truncated_chunk_fails_closed(tmp_path):
    g, q, store = _mk(tmp_path)
    eng = SubgraphQueryEngine(store.snapshot())
    ref = eng.query(q)[0]
    assert ref.shape[0] > 0
    bak = _backup(store, tmp_path)
    for fp in _chunk_files(store):
        with open(fp, "r+b") as f:
            f.truncate(os.path.getsize(fp) - 8)
    _cold(store)
    with pytest.raises(ChunkIOError, match="bytes"):
        eng.query(q)
    # repair → the same snapshot answers the same query with the same rows
    _restore(store, bak)
    np.testing.assert_array_equal(eng.query(q)[0], ref)


def test_corrupted_chunk_header_fails_closed(tmp_path):
    g, q, store = _mk(tmp_path)
    eng = SubgraphQueryEngine(store.snapshot())
    ref = eng.query(q)[0]
    bak = _backup(store, tmp_path)
    for fp in _chunk_files(store):
        with open(fp, "r+b") as f:
            f.write(b"\xde\xad\xbe\xef\xde\xad\xbe\xef")  # clobber the magic
    _cold(store)
    with pytest.raises(ChunkIOError, match="magic"):
        eng.query(q)
    _restore(store, bak)
    np.testing.assert_array_equal(eng.query(q)[0], ref)


def test_chunk_header_manifest_disagreement(tmp_path):
    """Bytes that are *internally* valid but disagree with the manifest
    (here: a chunk's lo_min bumped) must still fail closed."""
    g, q, store = _mk(tmp_path)
    eng = SubgraphQueryEngine(store.snapshot())
    for fp in _chunk_files(store):
        with open(fp, "r+b") as f:
            f.seek(2 * 8)  # header word 2 = lo_min
            f.write(np.int64(_V + 7).tobytes())
    _cold(store)
    with pytest.raises(ChunkIOError, match="disagrees"):
        eng.query(q)


def test_missing_chunk_file_fails_closed(tmp_path):
    g, q, store = _mk(tmp_path)
    eng = SubgraphQueryEngine(store.snapshot())
    for fp in _chunk_files(store):
        os.remove(fp)
    _cold(store)
    with pytest.raises(ChunkIOError, match="missing"):
        eng.query(q)


# ---------------------------------------------------------------------------
# manifest faults → typed error at open time
# ---------------------------------------------------------------------------


def test_manifest_missing_entry_field(tmp_path):
    _g, _q, store = _mk(tmp_path)
    mpath = os.path.join(store._base.path, gio.MANIFEST_NAME)
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["chunks"][0]["n_records"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ChunkIOError, match="missing"):
        OutOfCoreGraphStore.open(str(tmp_path / "store"))


def test_manifest_absent_or_invalid(tmp_path):
    _g, _q, store = _mk(tmp_path)
    mpath = os.path.join(store._base.path, gio.MANIFEST_NAME)
    with open(mpath, "w") as f:
        f.write("{ not json")
    with pytest.raises(ChunkIOError, match="JSON"):
        OutOfCoreGraphStore.open(str(tmp_path / "store"))
    os.remove(mpath)
    with pytest.raises(ChunkIOError, match="manifest"):
        OutOfCoreGraphStore.open(str(tmp_path / "store"))
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(ChunkIOError, match="no gen-"):
        OutOfCoreGraphStore.open(str(empty))


def test_open_rejects_mismatched_sidecars(tmp_path):
    """A vlabels sidecar that drifted from the manifest's vertex count is a
    corrupt store, not a different graph."""
    _g, _q, store = _mk(tmp_path)
    vpath = os.path.join(store._base.path, "vlabels.bin")
    with open(vpath, "r+b") as f:
        f.truncate(os.path.getsize(vpath) - 8)
    with pytest.raises(ChunkIOError):
        OutOfCoreGraphStore.open(str(tmp_path / "store"))


# ---------------------------------------------------------------------------
# simulated I/O failure mid-query → typed error, then full recovery
# ---------------------------------------------------------------------------


def test_simulated_read_failure_mid_query(tmp_path, monkeypatch):
    """An OS-level read error *during* the prefiltered fetch (np.memmap
    raising) surfaces as ChunkIOError; once the fault clears, the same
    engine over the same snapshot returns the original rows."""
    g, q, store = _mk(tmp_path)
    eng = SubgraphQueryEngine(store.snapshot())
    ref = eng.query(q)[0]
    _cold(store)
    real_memmap = np.memmap

    def flaky(*args, **kw):
        raise OSError("simulated device read failure")

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(gio.np, "memmap", flaky)
        with pytest.raises(ChunkIOError, match="could not be mapped"):
            eng.query(q)
    assert np.memmap is real_memmap
    _cold(store)
    np.testing.assert_array_equal(eng.query(q)[0], ref)


def test_service_releases_pins_on_chunk_failure(tmp_path):
    """A chunk failure during admission frees the slot and releases the
    epoch pin; the service keeps serving once the fault clears."""
    g, q, store = _mk(tmp_path)
    svc = GraphQueryService(store, GraphServiceConfig(
        max_slots=2, max_query_vertices=8, max_query_labels=8,
    ))
    rid1 = svc.submit(q)
    done = svc.run_to_completion()
    assert [r for r, _, _ in done] == [rid1]
    assert store._pins == {}

    # a mutation opens a new epoch, so the next admission must refetch
    lo, hi, _lab = (np.asarray(a) for a in store.alive_edges())
    svc.remove_edges(np.stack([lo[:3], hi[:3]], axis=1))
    _cold(store)

    def boom(path, entry, n_vertices):
        raise ChunkIOError("simulated chunk failure")

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(ooc_mod, "read_chunk", boom)
        svc.submit(q)
        with pytest.raises(ChunkIOError, match="simulated"):
            svc.tick()
    assert svc.n_active == 0  # the failed slot was freed...
    assert store._pins == {}  # ...and its epoch pin released

    # fault cleared: the same query on the same service now completes, and
    # matches a fresh engine over the store's current state bit-for-bit
    rid3 = svc.submit(q)
    done = svc.run_to_completion()
    assert [r for r, _, _ in done] == [rid3]
    ref = SubgraphQueryEngine(store.snapshot()).query(q)[0]
    np.testing.assert_array_equal(done[0][1], ref)
    assert done[0][2].extras["ooc"]["chunks_read"] >= 0


def test_failure_telemetry_survives_chunk_fault(tmp_path):
    """Regression: the ChunkIOError slot-release path used to drop all
    telemetry (ooc counters were only attached to *successful* results).
    The service must record a ``FailedRequest`` carrying the queue wait
    and the fetch's partial ``OocReport`` before the error propagates,
    and ``shutdown(drain=False)`` must attach each in-flight request's
    accumulated epoch IO telemetry to its ``CancelledRequest``."""
    from repro import obsv
    from repro.serve import FailedRequest

    g, q, store = _mk(tmp_path)
    svc = GraphQueryService(store, GraphServiceConfig(
        max_slots=2, max_query_vertices=8, max_query_labels=8,
    ))
    _cold(store)

    def boom(path, entry, n_vertices):
        raise ChunkIOError("simulated chunk failure")

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(ooc_mod, "read_chunk", boom)
        rid = svc.submit(q)
        with pytest.raises(ChunkIOError, match="simulated"):
            svc.tick()
    assert [f.rid for f in svc.failures] == [rid]
    fail = svc.failures[0]
    assert isinstance(fail, FailedRequest)
    assert "simulated chunk failure" in fail.reason
    assert fail.queued_seconds >= 0.0
    # the partial report covers the work done before the fault: the cold
    # cache meant the very first chunk access failed — one attempted read,
    # zero bytes and zero edges actually landed
    assert isinstance(fail.ooc, obsv.OocReport)
    assert fail.ooc["partial"] is True
    assert fail.ooc["chunks_read"] == 1
    assert fail.ooc["bytes_read"] == 0
    assert fail.ooc["edges_fetched"] == 0
    assert fail.ooc["fetch_seconds"] >= 0.0
    counts = svc.metrics_snapshot()["repro_service_requests_total"]
    assert counts["series"][(("status", "failed"),)] == 1

    # fault cleared: admit a request (epoch fetch succeeds, telemetry
    # accumulates), then cancel it in-flight — the partial IO work done on
    # its behalf must surface on the CancelledRequest, not vanish
    _cold(store)
    rid2 = svc.submit(q)
    svc._admit()
    assert svc.n_active == 1
    _finished, cancelled = svc.shutdown(drain=False)
    by_rid = {c.rid: c for c in cancelled}
    assert by_rid[rid2].reason == "shutdown before completion"
    assert isinstance(by_rid[rid2].ooc, obsv.OocReport)
    assert by_rid[rid2].ooc["chunks_read"] > 0
    assert by_rid[rid2].ooc["partial"] is False


def test_batch_engine_fails_closed(tmp_path):
    """The batch path fetches through the same loader — same typed error,
    and the snapshot stays usable afterwards."""
    from repro.core.batch_engine import BatchQueryEngine

    g, q, store = _mk(tmp_path)
    eng = BatchQueryEngine(store.snapshot())
    ref = eng.query_batch([q])[0][0]
    _cold(store)
    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(ooc_mod, "read_chunk",
                   lambda *a: (_ for _ in ()).throw(
                       ChunkIOError("simulated chunk failure")))
        with pytest.raises(ChunkIOError, match="simulated"):
            eng.query_batch([q])
    _cold(store)
    np.testing.assert_array_equal(eng.query_batch([q])[0][0], ref)
