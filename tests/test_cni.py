"""CNI encoding: bijection, monotonicity, saturation soundness (Theorem 1,
Lemmas 3-5 of the paper + the DESIGN.md §1 corrections)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cni import (
    SAT64,
    _pascal_table_np,
    cni_exact_py,
    cni_from_counts,
    cni_log_from_counts,
    default_max_p,
    limb_to_u64_np,
)


def _cni_u64(counts_row, d_max, max_p):
    c = jnp.asarray(np.asarray(counts_row, np.int32)[None, :])
    v = cni_from_counts(c, d_max, max_p)
    return int(limb_to_u64_np(v.hi, v.lo)[0])


class TestPascalTable:
    def test_exact_small(self):
        t = _pascal_table_np(10, 60)
        for q in range(1, 11):
            for p in range(1, 61):
                assert int(t[q, p]) == math.comb(q + p - 1, q)

    def test_zero_convention(self):
        t = _pascal_table_np(6, 20)
        assert (t[1:, 0] == 0).all()

    def test_saturation_sticky_monotone(self):
        t = _pascal_table_np(40, 2000)
        # rows are monotone nondecreasing in p even where saturated
        for q in range(1, 41):
            row = t[q].astype(np.float64)
            assert (np.diff(row) >= 0).all()
        assert (t <= SAT64).all()


class TestBijection:
    @given(
        st.lists(st.integers(min_value=0, max_value=3), min_size=4, max_size=4)
    )
    @settings(max_examples=200, deadline=None)
    def test_matches_arbitrary_precision_oracle(self, counts):
        L, D = 4, 12
        labels = [l for l, c in enumerate(counts, start=1) for _ in range(c)]
        expect = cni_exact_py(labels)
        got = _cni_u64(counts, D, default_max_p(D, L))
        assert got == expect

    def test_injective_below_saturation(self):
        # all count vectors with small sums must encode distinctly unless the
        # multisets are equal — Theorem 1 restricted to equal-degree rows
        L, D = 3, 8
        seen = {}
        for a in range(4):
            for b in range(4):
                for c in range(4):
                    key = _cni_u64([a, b, c], D, default_max_p(D, L))
                    deg = a + b + c
                    if (deg, key) in seen:
                        assert seen[(deg, key)] == (a, b, c), (
                            "collision at equal degree"
                        )
                    seen[(deg, key)] = (a, b, c)


class TestMonotonicity:
    """Lemma 3: multiset inclusion ⇒ CNI(v) >= CNI(u) (descending order)."""

    @given(
        st.lists(st.integers(min_value=0, max_value=4), min_size=5, max_size=5),
        st.integers(min_value=0, max_value=4),
    )
    @settings(max_examples=300, deadline=None)
    def test_superset_has_geq_cni(self, base, extra_label):
        L, D = 5, 32
        sup = list(base)
        sup[extra_label] += 1
        mp = default_max_p(D, L)
        assert _cni_u64(sup, D, mp) >= _cni_u64(base, D, mp)

    @given(
        st.lists(st.integers(min_value=0, max_value=3), min_size=4, max_size=4),
        st.lists(st.integers(min_value=0, max_value=2), min_size=4, max_size=4),
    )
    @settings(max_examples=300, deadline=None)
    def test_componentwise_domination(self, base, delta):
        L, D = 4, 24
        sup = [b + d for b, d in zip(base, delta)]
        mp = default_max_p(D, L)
        assert _cni_u64(sup, D, mp) >= _cni_u64(base, D, mp)

    @given(
        st.lists(st.integers(min_value=1, max_value=6), min_size=1, max_size=6),
        st.integers(min_value=1, max_value=6),
    )
    @settings(max_examples=200, deadline=None)
    def test_descending_gives_per_term_domination(self, base, extra):
        """DESIGN.md §1: with *descending* prefix sums, inserting a label makes
        every positional term weakly larger — the property that keeps the
        filter sound even under the clipped (min(p, max_p)) Pascal table.
        (Ascending order only guarantees aggregate monotonicity via the
        dominant last term, which clipping can in principle defeat.)"""

        def terms_desc(labels):
            xs = sorted(labels, reverse=True)
            out, s = [], 0
            for j, x in enumerate(xs, start=1):
                s += x
                out.append(math.comb(j + s - 1, j))
            return out

        t_base = terms_desc(base)
        t_sup = terms_desc(base + [extra])
        assert len(t_sup) == len(t_base) + 1
        for a, b in zip(t_base, t_sup):
            assert b >= a, (base, extra, t_base, t_sup)


class TestLogSpace:
    @given(
        st.lists(st.integers(min_value=0, max_value=4), min_size=5, max_size=5),
        st.integers(min_value=0, max_value=4),
    )
    @settings(max_examples=200, deadline=None)
    def test_log_monotone_with_tolerance(self, base, extra):
        L, D = 5, 32
        sup = list(base)
        sup[extra] += 1
        mp = default_max_p(D, L)
        both = jnp.asarray(np.asarray([base, sup], np.int32))
        vals = cni_log_from_counts(both, D, mp)
        lo, hi = float(vals[0]), float(vals[1])
        if not np.isfinite(lo):
            return  # empty base row
        assert hi >= lo - 1e-4 * max(1.0, abs(lo))

    def test_equal_multisets_equal_logs(self):
        c = jnp.asarray(np.asarray([[2, 0, 1], [2, 0, 1]], np.int32))
        v = cni_log_from_counts(c, 8, default_max_p(8, 3))
        assert float(v[0]) == float(v[1])


class TestSaturationSoundness:
    def test_saturated_compare_is_weak_not_wrong(self):
        # giant counts saturate; superset must still compare >= (never <)
        L, D = 4, 64
        mp = default_max_p(D, L)
        base = [10, 10, 10, 10]
        sup = [10, 10, 10, 11]
        assert _cni_u64(sup, D, mp) >= _cni_u64(base, D, mp)

    def test_paper_running_example_k2(self):
        # Appendix C worked example: cni_2(u1) = ħ(1,3) + ħ(2,4) = 3 + 10 ...
        # the paper says 7 using ħ(1,3)=3? C(3,1)=3, ħ(2,4)=C(5,2)=10 → 13.
        # The paper's arithmetic ("= 7") is internally inconsistent; we pin
        # our (correct) formula instead: labels {3, 1} descending = [3, 1].
        assert cni_exact_py([3, 1]) == math.comb(3, 1) + math.comb(5, 2)
