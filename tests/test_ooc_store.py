"""Out-of-core store tier: on-disk format, cache, generations, resident set.

Complements tests/test_differential.py (bit-parity of query *results*) and
tests/test_ooc_faults.py (fail-closed corruption handling) with the tier's
own mechanics:

* edge-file header validation + write/read round trips in both
  ``sorted_by_src`` modes (the bugfix for silently-short reads);
* chunk-directory round trips, manifest interval bounds, writer validation;
* LRU cache byte accounting and eviction under a tiny budget;
* generation lifecycle: compaction, epoch pins keeping old generations'
  chunk files on disk until released, then GC;
* chunk-interval pruning: a vertex-localized query touches a strict subset
  of chunks;
* the streaming index/stats rebuild matching the in-memory build;
* (slow tier) a graph ~20x larger than the resident budget queried with
  the process resident set growing by far less than the edge table.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from hypothesis import given, settings

import repro.graphs.io as gio
from repro.core.engine import SubgraphQueryEngine
from repro.core.incremental import IncrementalIndex
from repro.core.stats import GraphStats
from repro.graphs import (
    ChunkDirWriter,
    ChunkIOError,
    GraphStore,
    OutOfCoreGraphStore,
    random_labeled_graph,
    random_walk_query,
    read_edge_file,
    stream_edge_chunks,
    write_chunk_dir,
    write_edge_file,
)
from repro.graphs.csr import build_graph
from strategies import emb_set, graph_query_seeds, peak_rss_bytes

_V, _E = 36, 90


def _graph(seed=0, n_vertices=_V, n_edges=_E):
    return random_labeled_graph(
        n_vertices, n_edges, 3, n_edge_labels=2, seed=seed
    )


def _edge_multiset(g):
    return sorted(zip(np.asarray(g.src).tolist(),
                      np.asarray(g.dst).tolist(),
                      np.asarray(g.elabels).tolist()))


# ---------------------------------------------------------------------------
# edge-file header validation + round trip (both sorted_by_src modes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sorted_by_src", [True, False])
def test_edge_file_round_trip(tmp_path, sorted_by_src):
    g = _graph()
    path = str(tmp_path / "g.bin")
    write_edge_file(path, g, sorted_by_src=sorted_by_src)
    back = read_edge_file(path)
    assert np.array_equal(np.asarray(back.vlabels), np.asarray(g.vlabels))
    assert _edge_multiset(back) == _edge_multiset(g)
    # the streaming reader yields exactly the same records, padded
    rows = []
    for s, d, e, valid in stream_edge_chunks(path, 32):
        rows += list(zip(s[valid].tolist(), d[valid].tolist(),
                         e[valid].tolist()))
    assert sorted(rows) == _edge_multiset(g)


@settings(max_examples=10, deadline=None)
@given(graph_query_seeds())
def test_edge_file_round_trip_property(tmp_path_factory, seed):
    """Property form: random graphs round-trip bit-exactly through the
    edge-file format in both record orders."""
    g = _graph(seed=seed, n_edges=40 + seed % 97)
    path = str(tmp_path_factory.mktemp("ef") / "g.bin")
    for sorted_by_src in (True, False):
        write_edge_file(path, g, sorted_by_src=sorted_by_src)
        back = read_edge_file(path)
        assert np.array_equal(np.asarray(back.vlabels),
                              np.asarray(g.vlabels))
        assert _edge_multiset(back) == _edge_multiset(g)


def test_edge_file_header_validation(tmp_path):
    """The header used to be trusted outright — a truncated file yielded a
    silently smaller edge set.  Every mismatch is now a typed error."""
    g = _graph()
    path = str(tmp_path / "g.bin")
    write_edge_file(path, g)
    good = os.path.getsize(path)

    with open(path, "r+b") as f:        # truncated mid-record
        f.truncate(good - 10)
    with pytest.raises(ChunkIOError, match="requires"):
        read_edge_file(path)
    with pytest.raises(ChunkIOError):
        list(stream_edge_chunks(path, 16))

    write_edge_file(path, g)
    with open(path, "ab") as f:         # trailing garbage
        f.write(b"\x00" * 7)
    with pytest.raises(ChunkIOError, match="requires"):
        read_edge_file(path)

    write_edge_file(path, g)
    with open(path, "r+b") as f:        # negative count in the header
        f.seek(8)
        f.write(np.int64(-4).tobytes())
    with pytest.raises(ChunkIOError, match="corrupt"):
        read_edge_file(path)

    with open(path, "wb") as f:         # too short for any header
        f.write(b"\x01\x02")
    with pytest.raises(ChunkIOError, match="too short"):
        read_edge_file(path)

    with pytest.raises(ChunkIOError, match="missing"):
        read_edge_file(str(tmp_path / "nope.bin"))


# ---------------------------------------------------------------------------
# chunk directory format
# ---------------------------------------------------------------------------


def _canonical_edges(g):
    lo = np.minimum(np.asarray(g.src), np.asarray(g.dst))
    hi = np.maximum(np.asarray(g.src), np.asarray(g.dst))
    keep = lo < hi
    lo, hi = lo[keep], hi[keep]
    lab = np.asarray(g.elabels)[keep]
    key = lo.astype(np.int64) * g.n_vertices + hi
    _, first = np.unique(key, return_index=True)
    return lo[first], hi[first], lab[first]


@pytest.mark.parametrize("chunk_edges", [7, 16, 10_000])
def test_chunk_dir_round_trip(tmp_path, chunk_edges):
    """write_chunk_dir → manifest + per-chunk reads recover the exact
    record stream; manifest interval bounds are tight."""
    g = _graph()
    lo, hi, lab = _canonical_edges(g)
    root = str(tmp_path / "cd")
    manifest = write_chunk_dir(root, g.n_vertices, np.asarray(g.vlabels),
                               lo, hi, lab, chunk_edges=chunk_edges)
    assert manifest["n_records"] == lo.size
    got = []
    for entry in manifest["chunks"]:
        rec = gio.read_chunk(root, entry, g.n_vertices)
        assert rec.shape == (entry["n_records"], 3)
        assert rec[:, 0].min() == entry["lo_min"]
        assert rec[:, 0].max() == entry["lo_max"]
        assert rec[:, 1].min() == entry["hi_min"]
        assert rec[:, 1].max() == entry["hi_max"]
        got.append(rec)
    rec = np.concatenate(got) if got else np.zeros((0, 3), np.int64)
    order = np.lexsort((hi, lo))
    np.testing.assert_array_equal(
        rec, np.stack([lo[order], hi[order], lab[order]], axis=1)
    )
    # every chunk but the last is exactly chunk_edges records
    for entry in manifest["chunks"][:-1]:
        assert entry["n_records"] == chunk_edges


def test_chunk_dir_writer_validates(tmp_path):
    w = ChunkDirWriter(str(tmp_path / "cd"), 10, np.zeros(10, np.int64))
    w.add([0], [3], [1])
    with pytest.raises(ValueError, match="canonical"):
        w.add([5], [5], [0])            # lo == hi
    with pytest.raises(ValueError, match="canonical"):
        w.add([3], [12], [0])           # out of range
    with pytest.raises(ValueError):
        w.add([0], [2], [0])            # key order violated
    w.add([0, 4], [4, 7], [0, 1])
    m = w.close()
    assert m["n_records"] == 3


# ---------------------------------------------------------------------------
# store mechanics: persistence, cache, generations, pruning
# ---------------------------------------------------------------------------


def test_store_persist_and_open(tmp_path):
    g = _graph()
    q = random_walk_query(g, 4, seed=1)
    root = str(tmp_path / "store")
    store = OutOfCoreGraphStore.from_graph(g, storage_dir=root,
                                           chunk_edges=16)
    ref = SubgraphQueryEngine(store.snapshot()).query(q)[0]
    n_edges, chunk_edges = store.n_edges, store.chunk_edges
    del store

    back = OutOfCoreGraphStore.open(root)
    assert back.n_edges == n_edges
    assert back.chunk_edges == chunk_edges  # adopted from the manifest
    np.testing.assert_array_equal(
        SubgraphQueryEngine(back.snapshot()).query(q)[0], ref
    )


def test_streaming_rebuild_matches_memory():
    """IncrementalIndex.rebuild and GraphStats.from_store consume the
    chunked stream; digests and aggregates equal the in-memory build."""
    g = _graph(seed=3)
    mem = GraphStore.from_graph(g)
    mem.attach_index(IncrementalIndex())
    ooc = OutOfCoreGraphStore.from_graph(g, chunk_edges=16)
    np.testing.assert_array_equal(mem.index.cni_u64, ooc.index.cni_u64)
    s_mem = GraphStats.from_store(mem)
    s_ooc = GraphStats.from_store(ooc)
    assert s_mem.n_edges == s_ooc.n_edges
    np.testing.assert_array_equal(s_mem.label_hist, s_ooc.label_hist)
    np.testing.assert_array_equal(s_mem.deg_sum, s_ooc.deg_sum)
    np.testing.assert_array_equal(s_mem.pair_counts, s_ooc.pair_counts)


def test_cache_eviction_under_budget(tmp_path):
    g = _graph(n_edges=300, n_vertices=60)
    store = OutOfCoreGraphStore.from_graph(
        g, storage_dir=str(tmp_path / "s"), chunk_edges=8,
        resident_budget_bytes=3 * 8 * 24,  # ~3 chunks
    )
    handle = store.snapshot().ooc
    chunk_bytes = 8 * 24
    for _ in range(2):  # full fetches cycle every chunk through the LRU
        graph, tel = handle.fetch_restricted(
            np.ones(store.n_vertices, bool))
        assert graph.src.size // 2 == store.n_edges
    c = store.cache
    assert c.misses > c.budget_bytes // chunk_bytes  # evictions forced reloads
    assert c.resident_bytes <= c.budget_bytes
    assert c.peak_resident_bytes <= c.budget_bytes + chunk_bytes
    assert c.bytes_read > c.budget_bytes  # re-reads, not one warm pass


def test_chunk_interval_pruning(tmp_path):
    """A query whose candidates live on a narrow vertex range touches only
    the chunks whose manifest intervals intersect it."""
    n = 4000
    v = n + 2
    vlab = np.zeros(v, np.int64)
    vlab[:8] = 1
    i = np.arange(n, dtype=np.int64)
    lo = np.repeat(i, 2)
    hi = np.empty_like(lo)
    hi[0::2] = i + 1
    hi[1::2] = i + 2
    g = build_graph(v, vlab, np.stack([lo, hi], axis=1),
                    elabels=np.zeros(lo.size, np.int64))
    store = OutOfCoreGraphStore.from_graph(g, chunk_edges=256)
    assert store.n_chunks > 10
    q = build_graph(3, [1, 1, 1], [(0, 1), (1, 2)])
    emb, stats = SubgraphQueryEngine(store.snapshot()).query(q)
    assert emb.shape[0] > 0
    tel = stats.extras["ooc"]
    assert tel["chunks_read"] < tel["n_chunks"] // 4, tel
    assert emb_set(emb) == emb_set(
        SubgraphQueryEngine(g).query(q)[0]
    )


def test_epoch_pin_keeps_generation_files(tmp_path):
    """Compaction must not pull chunk files out from under a pinned epoch:
    the old generation's directory survives on disk until the pin drops,
    and queries against the pinned snapshot keep answering from it."""
    import gc

    g = _graph()
    q = random_walk_query(g, 4, seed=1)
    root = str(tmp_path / "store")
    store = OutOfCoreGraphStore.from_graph(g, storage_dir=root,
                                           chunk_edges=16)
    snap0 = store.pin()
    old_gen_dir = store._base.path
    ref = SubgraphQueryEngine(snap0).query(q)[0]

    lo, hi, _lab = (np.asarray(a) for a in store.alive_edges())
    store.remove_edges(np.stack([lo[:5], hi[:5]], axis=1))
    assert store.compact() > 0
    assert store._base.path != old_gen_dir
    assert os.path.isdir(old_gen_dir)  # pinned epoch still needs it

    store.cache.drop_generation(snap0.ooc.base.gen_id)  # force disk reads
    np.testing.assert_array_equal(
        SubgraphQueryEngine(snap0).query(q)[0], ref
    )

    store.release(snap0.epoch)
    del snap0
    gc.collect()
    store.snapshot()  # GC sweep runs on snapshot traffic
    assert not os.path.isdir(old_gen_dir)


def test_all_dead_prefilter_reads_nothing(tmp_path):
    g = _graph()
    store = OutOfCoreGraphStore.from_graph(g, chunk_edges=16)
    handle = store.snapshot().ooc
    graph, tel = handle.fetch_restricted(np.zeros(store.n_vertices, bool))
    assert graph.src.size == 0
    assert tel["chunks_read"] == 0 and tel["bytes_read"] == 0


# ---------------------------------------------------------------------------
# slow tier: resident set stays bounded on a ~20x-over-budget graph
# ---------------------------------------------------------------------------


_RESIDENT_SET_SCRIPT = r"""
import os, sys, types
try:
    import hypothesis  # noqa: F401
except ImportError:  # mirror tests/conftest.py's shim for strategies import
    h = types.ModuleType("hypothesis"); h.__is_repro_shim__ = True
    st = types.ModuleType("hypothesis.strategies"); h.strategies = st
    sys.modules["hypothesis"] = h; sys.modules["hypothesis.strategies"] = st
import numpy as np
from strategies import peak_rss_bytes
from repro.graphs import OutOfCoreGraphStore
from repro.graphs.io import ChunkDirWriter
from repro.graphs.csr import build_graph
from repro.core.engine import SubgraphQueryEngine

root = sys.argv[1]
N = 450_000
V = N + 2
BUDGET = 1 << 20  # 1 MiB chunk-cache budget

# stream a two-spine path graph to disk without materializing it: rare
# label 1 on vertices 0..9, so a label-1 query is prunable to one chunk
vlab = np.zeros(V, np.int64)
vlab[:10] = 1
w = ChunkDirWriter(os.path.join(root, "gen-00000"), V, vlab,
                   chunk_edges=4096)
B = 8192
for start in range(0, N, B):
    i = np.arange(start, min(start + B, N), dtype=np.int64)
    lo = np.repeat(i, 2)
    hi = np.empty_like(lo)
    hi[0::2] = i + 1
    hi[1::2] = i + 2
    w.add(lo, hi, np.zeros(lo.size, np.int64))
manifest = w.close()
disk_bytes = 24 * manifest["n_records"]
assert disk_bytes >= 10 * BUDGET, (disk_bytes, BUDGET)

store = OutOfCoreGraphStore.open(root, resident_budget_bytes=BUDGET)
assert store.n_edges == manifest["n_records"]
q = build_graph(3, [1, 1, 1], [(0, 1), (1, 2)])
eng = SubgraphQueryEngine(store.snapshot())
# warm the jit traces and let the device allocator reach steady state
# before taking the high-water baseline
emb0, _ = eng.query(q)
eng.query(q)
base = peak_rss_bytes()

emb, stats = eng.query(q)
tel = stats.extras["ooc"]
assert emb.shape[0] > 0 and emb.shape == emb0.shape
assert tel["chunks_read"] < tel["n_chunks"], tel          # pruning canary
assert tel["n_chunks"] == len(manifest["chunks"])
assert store.cache.peak_resident_bytes <= BUDGET + 4096 * 24
# the query's working set must be nowhere near the on-disk edge table
delta = peak_rss_bytes() - base
assert delta < disk_bytes // 2, (delta, disk_bytes)
print("OK edges=%d chunks=%d/%d delta=%d" % (
    store.n_edges, tel["chunks_read"], tel["n_chunks"], delta))
"""


@pytest.mark.slow
def test_resident_set_bounded_subprocess(tmp_path):
    """A graph ~20x the chunk-cache budget, built and queried in a fresh
    subprocess (``ru_maxrss`` is a monotone high-water mark, so only a
    clean process gives a meaningful delta)."""
    assert peak_rss_bytes() > 0  # the helper itself works in-process
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([
        os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                     "src")),
        os.path.dirname(os.path.abspath(__file__)),
    ])
    out = subprocess.run(
        [sys.executable, "-c", _RESIDENT_SET_SCRIPT, str(tmp_path / "big")],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
