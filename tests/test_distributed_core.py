"""Distributed / sharded CNI engine tests.

Host-side sharding (store + index parity) runs in-process.  Anything that
needs more than one XLA device runs in a subprocess with
``--xla_force_host_platform_device_count`` so the rest of the suite keeps
seeing exactly one device, per launch rules: the fast-tier test forces 4
virtual devices (the CI acceptance gate for 1/2/4-shard bit-identity), the
slow test keeps the original 8-device sweep.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import IncrementalIndex, ShardedIncrementalIndex
from repro.graphs import (
    GraphStore,
    ShardedGraphStore,
    random_labeled_graph,
    random_update_batches,
)


def _run_forced_devices(script: str, n_devices: int, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}"
    )
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Host-side: sharded store + sharded index == unsharded twins, bit for bit.
# ---------------------------------------------------------------------------


class TestShardedStoreParity:
    def _pair(self, n_shards=4, **kwargs):
        g = random_labeled_graph(220, 700, 6, n_edge_labels=2, seed=0)
        ref = GraphStore.from_graph(g, **kwargs)
        ref.attach_index(IncrementalIndex())
        sh = ShardedGraphStore.from_graph(g, n_shards=n_shards, **kwargs)
        sh.attach_index(ShardedIncrementalIndex())
        return g, ref, sh

    def _assert_state_equal(self, ref, sh):
        s1, s2 = ref.snapshot(), sh.snapshot()
        for f in ("vlabels", "src", "dst", "elabels"):
            assert (
                np.asarray(getattr(s1.graph, f))
                == np.asarray(getattr(s2.graph, f))
            ).all(), f
        assert (ref.degrees() == sh.degrees()).all()
        i1, i2 = s1.index, s2.index
        assert (i1.counts == i2.counts).all()
        assert (i1.deg == i2.deg).all()
        assert (i1.cni_u64 == i2.cni_u64).all()       # exact-limb digests
        assert (i1.cni_log == i2.cni_log).all()       # log digests, bitwise
        assert i1.d_max == i2.d_max and i1.max_p == i2.max_p

    def test_mutation_stream_bit_identical(self):
        g, ref, sh = self._pair(compact_every=5)
        for b in random_update_batches(g, 14, 48, delete_frac=0.4, seed=1):
            r1 = ref.apply(b)
            r2 = sh.apply(b)
            assert (r1.epoch, r1.n_inserted, r1.n_deleted, r1.n_skipped) == (
                r2.epoch, r2.n_inserted, r2.n_deleted, r2.n_skipped
            )
            # applied records agree as *sets* (shards commit in owner order)
            k1 = set(zip(r1.applied.src, r1.applied.dst, r1.applied.insert))
            k2 = set(zip(r2.applied.src, r2.applied.dst, r2.applied.insert))
            assert k1 == k2
        self._assert_state_equal(ref, sh)

    def test_cross_shard_batches_update_both_owners(self):
        g, ref, sh = self._pair()
        plan = sh.plan
        # build a batch whose every edge crosses a shard boundary
        rng = np.random.default_rng(3)
        lo = rng.integers(0, plan.v_local, size=24)                 # shard 0
        hi = rng.integers(plan.v_local, 220, size=24)               # others
        batch_edges = np.stack([lo, hi], axis=1)
        ref.add_edges(batch_edges)
        before = sh.index.stats.boundary_exchanged
        sh.add_edges(batch_edges)
        assert sh.index.stats.boundary_exchanged > before
        assert sh.n_boundary_edges > 0
        self._assert_state_equal(ref, sh)
        # ghost lists: every cross-shard endpoint is registered on its
        # partner shard
        stats = sh.shard_stats()
        assert any(s.n_ghosts > 0 for s in stats)

    def test_snapshot_carries_shard_tables(self):
        g, _, sh = self._pair()
        snap = sh.snapshot()
        assert snap.shards is not None and len(snap.shards) == 4
        # shard tables partition the canonical edge set by owner(lo)
        lo_all = np.concatenate([t[0] for t in snap.shards])
        hi_all = np.concatenate([t[1] for t in snap.shards])
        assert lo_all.size == sh.n_edges
        assert (lo_all < hi_all).all()
        for i, t in enumerate(snap.shards):
            assert (sh.plan.owner(t[0]) == i).all()

    def test_epoch_consistency_and_pins(self):
        g, _, sh = self._pair()
        snap0 = sh.pin()
        e0 = snap0.graph.n_edges
        sh.add_edges([[0, 219], [1, 218]])
        assert sh.epoch == snap0.epoch + 1
        assert snap0.graph.n_edges == e0  # pinned view untouched
        assert sh.snapshot().graph.n_edges == e0 + 2
        sh.release(snap0.epoch)

    def test_degree_cap_atomicity(self):
        g = random_labeled_graph(60, 120, 4, seed=5)
        sh = ShardedGraphStore.from_graph(g, n_shards=2, degree_cap=None)
        sh.degree_cap = int(sh.max_degree)
        hub = int(np.argmax(sh.degrees()))
        other = (hub + 1) % 60 if not sh.has_edge(hub, (hub + 1) % 60) else (
            (hub + 2) % 60
        )
        before = sh.stats()
        with pytest.raises(ValueError):
            sh.add_edges([[hub, other]])
        after = sh.stats()
        assert before == after  # nothing mutated


class TestShardedIndexAutoGrow:
    def test_d_max_overflow_rebuild_matches_unsharded(self):
        g = random_labeled_graph(80, 160, 4, seed=0)
        ref = GraphStore.from_graph(g)
        ref.attach_index(IncrementalIndex())
        sh = ShardedGraphStore.from_graph(g, n_shards=3)
        sh.attach_index(ShardedIncrementalIndex())
        hub = 0  # push one hub far past the initial pow2 d_max bound
        edges = [[hub, v] for v in range(1, 70) if not ref.has_edge(hub, v)]
        ref.add_edges(edges)
        sh.add_edges(edges)
        i1, i2 = ref.index, sh.index
        assert i1.stats.full_rebuilds == i2.stats.full_rebuilds >= 1
        assert i1.d_max == i2.d_max and i1.max_p == i2.max_p
        assert (i1.counts == i2.counts).all()
        assert (i1.cni_u64 == i2.cni_u64).all()
        assert (i1.cni_log == i2.cni_log).all()
        assert (i1.deg == i2.deg).all()
        assert i1.stats.touched_vertices == i2.stats.touched_vertices


class TestShardedIndexSaturation:
    def test_saturation_rules_match_unsharded(self):
        # dense hub graph to push digests across the saturation boundary
        g = random_labeled_graph(120, 1400, 3, seed=7)
        ref = GraphStore.from_graph(g)
        ref.attach_index(IncrementalIndex())
        sh = ShardedGraphStore.from_graph(g, n_shards=3)
        sh.attach_index(ShardedIncrementalIndex())
        for b in random_update_batches(g, 10, 64, delete_frac=0.5, seed=8):
            ref.apply(b)
            sh.apply(b)
        i1, i2 = ref.index, sh.index
        assert (i1.cni_u64 == i2.cni_u64).all()
        assert (i1.cni_log == i2.cni_log).all()
        assert i1.stats.saturated_skips == i2.stats.saturated_skips
        assert i1.stats.saturated_recomputes == i2.stats.saturated_recomputes
        assert i1.stats.reencoded_vertices == i2.stats.reencoded_vertices


# ---------------------------------------------------------------------------
# Device-partitioned execution: 1/2/4 virtual devices, bit-identical to the
# single-device engine (fast tier — this is the CI acceptance gate).
# ---------------------------------------------------------------------------


_PARITY_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.core import (
        BatchQueryEngine, ShardedIncrementalIndex, SubgraphQueryEngine, ilgf,
    )
    from repro.core.distributed import device_mesh, distributed_ilgf
    from repro.graphs import (
        ShardedGraphStore, random_labeled_graph, random_update_batches,
        random_walk_query,
    )

    assert len(jax.devices()) == 4, jax.devices()

    g = random_labeled_graph(360, 1100, 6, n_edge_labels=2, seed=11)
    store = ShardedGraphStore.from_graph(g, n_shards=4)
    store.attach_index(ShardedIncrementalIndex())
    # mutation batches that cross shard boundaries (random endpoints span
    # the whole id range, so crossings dominate)
    for b in random_update_batches(g, 5, 48, delete_frac=0.3, seed=12):
        store.apply(b)
    assert store.n_boundary_edges > 0
    snap = store.snapshot()
    q = random_walk_query(snap.graph, 5, sparse=True, seed=13)

    ref = ilgf(snap.graph, q)
    for k in (1, 2, 4):
        mesh = device_mesh(k)
        dist = distributed_ilgf(store, q, mesh)
        assert (np.asarray(ref.alive) == np.asarray(dist.alive)).all(), k
        assert (
            np.asarray(ref.candidates) == np.asarray(dist.candidates)
        ).all(), k
        assert int(ref.iterations) == int(dist.iterations), k

    # end-to-end embedding sets, sequential + batched engines
    qs = [random_walk_query(snap.graph, 4, seed=20 + i) for i in range(4)]
    mesh = device_mesh(4)
    for query in qs[:2]:
        e_ref, _ = SubgraphQueryEngine(store).query(query)
        e_sh, _ = SubgraphQueryEngine(store, mesh=mesh).query(query)
        assert {tuple(r) for r in e_ref.tolist()} == {
            tuple(r) for r in e_sh.tolist()
        }
    r_ref = BatchQueryEngine(store).query_batch(qs)
    r_sh = BatchQueryEngine(store, mesh=mesh).query_batch(qs)
    for (e1, _), (e2, _) in zip(r_ref, r_sh):
        assert {tuple(r) for r in e1.tolist()} == {
            tuple(r) for r in e2.tolist()
        }
    print("SHARDED_PARITY_OK")
    """
)


def test_sharded_parity_1_2_4_devices():
    out = _run_forced_devices(_PARITY_SCRIPT, 4)
    assert "SHARDED_PARITY_OK" in out


_SERVICE_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.core import ShardedIncrementalIndex
    from repro.core.distributed import device_mesh
    from repro.graphs import (
        ShardedGraphStore, random_labeled_graph, random_walk_query,
    )
    from repro.serve import GraphQueryService, GraphServiceConfig

    assert len(jax.devices()) == 4

    g = random_labeled_graph(300, 900, 6, n_edge_labels=2, seed=0)
    qs = [random_walk_query(g, 5, seed=30 + i) for i in range(6)]

    def run(mesh):
        store = ShardedGraphStore.from_graph(g, n_shards=4, degree_cap=64)
        store.attach_index(ShardedIncrementalIndex())
        svc = GraphQueryService(store, GraphServiceConfig(
            max_slots=4, max_query_vertices=8, max_query_labels=8,
            mesh=mesh))
        for q in qs:
            svc.submit(q)
        out = {}
        ticks = 0
        while len(out) < len(qs) and ticks < 500:
            for rid, emb, _ in svc.tick():
                out[rid] = frozenset(map(tuple, emb.tolist()))
            ticks += 1
            if ticks == 2:  # live mutation mid-flight, crossing shards
                svc.add_edges([[0, 299], [1, 250]])
                svc.remove_edges([[0, 299]])
        svc.shutdown()
        return out

    assert run(None) == run(device_mesh(4))
    print("SHARDED_SERVICE_OK")
    """
)


def test_sharded_service_parity():
    out = _run_forced_devices(_SERVICE_SCRIPT, 4)
    assert "SHARDED_SERVICE_OK" in out


# ---------------------------------------------------------------------------
# Original 8-device sweep incl. the distributed join search (slow tier).
# ---------------------------------------------------------------------------


_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from repro.graphs import random_labeled_graph, random_walk_query
    from repro.core import ilgf, host_dfs_search, embeddings_equal
    from repro.core.distributed import distributed_ilgf, distributed_join_search
    from repro.graphs.csr import induced_subgraph
    from jax.sharding import Mesh

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))

    for gs, qs in [(11, 12), (21, 22), (31, 32)]:
        g = random_labeled_graph(500, 1600, 6, n_edge_labels=2, seed=gs)
        q = random_walk_query(g, 5, sparse=True, seed=qs)
        ref = ilgf(g, q)
        dist = distributed_ilgf(g, q, mesh)
        assert (np.asarray(ref.alive) == np.asarray(dist.alive)).all()
        assert (np.asarray(ref.candidates) == np.asarray(dist.candidates)).all()
        alive = np.asarray(ref.alive)
        if alive.sum() == 0:
            continue
        sub, _ = induced_subgraph(g, alive)
        cand = np.asarray(ref.candidates)[alive]
        truth = host_dfs_search(sub, q, cand)
        emb, ovf = distributed_join_search(sub, q, cand, mesh, cap=4096)
        assert not ovf
        assert embeddings_equal(truth, emb)
    print("DISTRIBUTED_OK")
    """
)


@pytest.mark.slow
def test_distributed_ilgf_and_join_multidevice():
    out = _run_forced_devices(_SCRIPT, 8)
    assert "DISTRIBUTED_OK" in out
