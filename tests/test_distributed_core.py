"""Distributed CNI engine tests (run in a subprocess with 8 host devices so
the rest of the suite keeps seeing exactly one device, per launch rules)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import numpy as np, jax
    from jax.sharding import Mesh
    from repro.graphs import random_labeled_graph, random_walk_query
    from repro.core import ilgf, host_dfs_search, embeddings_equal
    from repro.core.distributed import distributed_ilgf, distributed_join_search
    from repro.graphs.csr import induced_subgraph

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))

    for gs, qs in [(11, 12), (21, 22), (31, 32)]:
        g = random_labeled_graph(500, 1600, 6, n_edge_labels=2, seed=gs)
        q = random_walk_query(g, 5, sparse=True, seed=qs)
        ref = ilgf(g, q)
        dist = distributed_ilgf(g, q, mesh)
        assert (np.asarray(ref.alive) == np.asarray(dist.alive)).all()
        assert (np.asarray(ref.candidates) == np.asarray(dist.candidates)).all()
        alive = np.asarray(ref.alive)
        if alive.sum() == 0:
            continue
        sub, _ = induced_subgraph(g, alive)
        cand = np.asarray(ref.candidates)[alive]
        truth = host_dfs_search(sub, q, cand)
        emb, ovf = distributed_join_search(sub, q, cand, mesh, cap=4096)
        assert not ovf
        assert embeddings_equal(truth, emb)
    print("DISTRIBUTED_OK")
    """
)


@pytest.mark.slow
def test_distributed_ilgf_and_join_multidevice():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    assert "DISTRIBUTED_OK" in out.stdout
